"""Paper Table 1 proxy — language-modeling perplexity.

Byte-level LM on this repo's corpus (no external datasets in the container),
same backbone for every variant, matching the table's comparisons:

  attention          (the Transformer row)
  stlt-fixed         (Laplace-STLT, fixed S)
  stlt-adaptive      (Laplace-STLT, adaptive S_max, the paper's best)
  stlt-relevance     (the figure's softmax(R)V readout)
  stlt-frozen        (ablation anchor: non-learnable sigma/omega/T)

Reports validation PPL per variant (CSV: name, us_per_step, val_ppl).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, emit, train_eval
from repro.data import ByteCorpus
from repro.models import layers as L
from repro.models import transformer as T


def _val_ppl(cfg, corpus):
    def ev(params):
        ces = []
        for s in range(4):
            b = corpus.batch(1000 + s, 8, 128, split="val")
            logits, _ = T.apply_lm(params, cfg, jnp.asarray(b["inputs"]))
            ces.append(float(L.cross_entropy(logits, jnp.asarray(b["labels"]))))
        return float(np.exp(np.mean(ces)))
    return ev


def main(steps: int = 300, fast: bool = False):
    if fast:
        steps = min(steps, 150)
    corpus = ByteCorpus()
    batch_fn = lambda s: corpus.batch(s, 8, 128)
    variants = {
        "lm_ppl/attention": bench_cfg("attention"),
        "lm_ppl/stlt_fixed_S16": bench_cfg("stlt"),
        "lm_ppl/stlt_adaptive_S32": bench_cfg("stlt", stlt_nodes=32, stlt_adaptive=True),
        "lm_ppl/stlt_relevance": bench_cfg("stlt_relevance"),
        "lm_ppl/stlt_frozen_params": bench_cfg(
            "stlt", stlt_learnable_sigma=False, stlt_learnable_omega=False,
            stlt_learnable_T=False),
    }
    results = {}
    for name, cfg in variants.items():
        import time
        t0 = time.time()
        _, ppl, _ = train_eval(cfg, batch_fn, steps, eval_fn=_val_ppl(cfg, corpus))
        us = (time.time() - t0) / steps * 1e6
        emit(name, us, f"val_ppl={ppl:.2f}")
        results[name] = ppl
    return results


if __name__ == "__main__":
    main()
