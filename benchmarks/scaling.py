"""Paper §4.6 — computational efficiency and scalability.

1. Forward wall-time vs sequence length: STLT is O(N) (log-log slope ~1),
   attention is O(N^2) (slope -> 2 at large N).
2. Decode-state memory vs context: STLT state is O(S*d), constant in N;
   the attention KV cache grows linearly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, emit, time_fn
from repro.core import stlt as stlt_lib
from repro.models import attention as A
from repro.models import transformer as T
from repro.utils import tree_bytes

D_MODEL, HEADS = 128, 4


def _stlt_forward(N):
    cfg = stlt_lib.STLTConfig(d_model=D_MODEL, num_heads=HEADS, num_nodes=16,
                              chunk=128)
    params = stlt_lib.init_stlt(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, N, D_MODEL))
    fn = jax.jit(lambda xx: stlt_lib.apply_stlt(params, cfg, xx)[0])
    return time_fn(fn, x)


def _attn_forward(N):
    cfg = A.AttentionConfig(d_model=D_MODEL, num_heads=HEADS, num_kv_heads=HEADS,
                            blockwise_threshold=1 << 62)
    params = A.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, N, D_MODEL))
    fn = jax.jit(lambda xx: A.apply_attention(params, cfg, xx))
    return time_fn(fn, x)


def _slope(ns, ts):
    ln, lt = np.log(ns), np.log(ts)
    return float(np.polyfit(ln, lt, 1)[0])


def main(fast: bool = False):
    ns_stlt = [512, 1024, 2048, 4096] + ([] if fast else [8192, 16384])
    ns_attn = [512, 1024, 2048] + ([] if fast else [4096])
    t_stlt = []
    for n in ns_stlt:
        t = _stlt_forward(n)
        t_stlt.append(t)
        emit(f"scaling/stlt_fwd_N{n}", t, f"us_per_token={t/n:.2f}")
    t_attn = []
    for n in ns_attn:
        t = _attn_forward(n)
        t_attn.append(t)
        emit(f"scaling/attn_fwd_N{n}", t, f"us_per_token={t/n:.2f}")
    s_stlt = _slope(ns_stlt, t_stlt)
    s_attn = _slope(ns_attn, t_attn)
    emit("scaling/loglog_slope_stlt", 0, f"slope={s_stlt:.2f} (linear ~1)")
    emit("scaling/loglog_slope_attn", 0, f"slope={s_attn:.2f} (quadratic -> 2)")

    # decode-state memory vs context
    for mixer in ("stlt", "attention"):
        cfg = bench_cfg(mixer, d_model=D_MODEL, num_heads=HEADS, num_kv_heads=HEADS)
        sizes = {}
        for ctx in (2048, 65536, 524288):
            st = jax.eval_shape(lambda: T.init_decode_state(cfg, 1, ctx))
            sizes[ctx] = tree_bytes(st)
        growth = sizes[524288] / sizes[2048]
        emit(f"scaling/state_bytes_{mixer}", 0,
             f"ctx2k={sizes[2048]};ctx512k={sizes[524288]};growth={growth:.1f}x")
    return {"slope_stlt": s_stlt, "slope_attn": s_attn}


if __name__ == "__main__":
    main()
