"""Benchmark entry point: one harness per paper table/figure.

  python -m benchmarks.run [--full] [--only lm_ppl,ablations,...]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
Default is the fast profile (CPU-friendly); --full runs the longer
trainings used for the EXPERIMENTS.md numbers.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (ablations, kernels, lm_ppl, longqa, roofline,
                            scaling, serving, translation)

    suites = {
        "scaling": lambda: scaling.main(fast=fast),          # §4.6
        "lm_ppl": lambda: lm_ppl.main(fast=fast),            # Table 1
        "translation": lambda: translation.main(fast=fast),  # Table 2
        "longqa": lambda: longqa.main(fast=fast),            # Table 3
        "ablations": lambda: ablations.main(fast=fast),      # Table 4
        "roofline": lambda: roofline.main(fast=fast),        # §Roofline
        "serving": lambda: serving.main(fast=fast),          # §Perf continuous batching
        "kernels": lambda: kernels.main(fast=fast),          # §Perf kernel layer
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness alive; record the failure
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            raise
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
