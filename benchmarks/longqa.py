"""Paper Table 3 proxy — long-document QA (NarrativeQA stand-in).

Needle retrieval: a (key, value) pair is planted in a long distractor
stream; after the query marker the model must reproduce the value. F1 proxy
= answer-token accuracy. The STLT variant additionally evaluates at 2x the
training context via its streaming state (the paper's 128k-stream evaluation
scaled to CPU); fixed-context attention cannot without re-chunking.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, emit, train_eval
from repro.data import needle_batch
from repro.models import transformer as T

VOCAB, SEQ = 32, 64


def _answer_acc(cfg, seq_len, n=4):
    def ev(params):
        accs = []
        for s in range(n):
            b = needle_batch(7, 5_000 + s, 8, seq_len, VOCAB)
            logits, _ = T.apply_lm(params, cfg, jnp.asarray(b["inputs"]))
            pred = np.asarray(jnp.argmax(logits[:, -2], -1))
            accs.append((pred == b["answer"]).mean())
        return float(np.mean(accs))
    return ev


def main(steps: int = 1500, fast: bool = False):
    if fast:
        steps = min(steps, 800)
    batch_fn = lambda s: needle_batch(7, s, 8, SEQ, VOCAB)
    results = {}
    for name, cfg in {
        "longqa/attention": bench_cfg("attention", vocab=VOCAB),
        "longqa/stlt_adaptive": bench_cfg("stlt", vocab=VOCAB, stlt_nodes=32,
                                          stlt_adaptive=True),
        "longqa/stlt_relevance": bench_cfg("stlt_relevance", vocab=VOCAB),
    }.items():
        t0 = time.time()
        _, acc, params = train_eval(cfg, batch_fn, steps, lr=5e-3,
                                    eval_fn=_answer_acc(cfg, SEQ))
        us = (time.time() - t0) / steps * 1e6
        derived = f"answer_acc={acc:.3f}"
        if "stlt" in name and "relevance" not in name:
            acc2x = _answer_acc(cfg, SEQ * 2)(params)  # stream beyond train ctx
            derived += f";acc_2x_ctx={acc2x:.3f}"
        emit(name, us, derived)
        results[name] = acc
    return results


if __name__ == "__main__":
    main()
