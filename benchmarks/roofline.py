"""Roofline analysis (deliverable g): per (arch x shape) on the single-pod
mesh, derive the three roofline terms from the dry-run's compiled artifacts:

  compute   = HLO_FLOPs / (chips * peak_FLOPs)      [s]
  memory    = HLO_bytes / (chips * HBM_bw)          [s]
  collective= coll_bytes / (chips * link_bw)        [s]

Sources: cost_corrected (scan-trip-count-corrected cost_analysis; see
launch/dryrun.py) for flops/bytes; the partitioned-HLO collective parse for
collective bytes. NB: corrected metrics from the SPMD module are per-device,
so the per-chip division is already done — the chips factor cancels.

Also reports MODEL_FLOPS = 6*N_active*tokens (2*N_active for inference) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs, plus the dominant term and a
bottleneck note per cell.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = "results/dryrun"


def active_params(cfg) -> float:
    """Non-embedding, routing-active parameter count (for 6ND)."""
    from repro.launch import steps as steps_lib
    from repro.utils import tree_flatten_with_paths

    shapes = steps_lib.abstract_params(cfg)
    total = 0.0
    for path, leaf in tree_flatten_with_paths(shapes):
        n = float(np.prod(leaf.shape))
        if path.endswith("embed/embed"):
            continue  # lookup, not matmul
        if "/moe/" in path and path.endswith(("/w1", "/w2", "/w3")):
            n *= cfg.top_k / cfg.num_experts  # only routed experts compute
        total += n
    return total


def model_flops(cfg, shape, kind: str) -> float:
    n_active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if kind in ("train", "prefill") else 1)
    per_tok = 6.0 if kind == "train" else 2.0
    if cfg.family == "encdec" and kind == "train":
        tokens *= 2  # encoder + decoder streams
    return per_tok * n_active * tokens


def load_cells(mesh: str = "single"):
    base = os.path.join(RESULTS, mesh)
    cells = []
    if not os.path.isdir(base):
        return cells
    for fn in sorted(os.listdir(base)):
        with open(os.path.join(base, fn)) as f:
            cells.append(json.load(f))
    return cells


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("skipped"):
        return {"key": f"{rec['arch']}/{rec['shape']}", "skipped": rec["skipped"]}
    if not rec.get("ok"):
        return {"key": f"{rec['arch']}/{rec['shape']}", "error": rec.get("error")}
    from repro import configs as configs_lib

    cfg = configs_lib.get_config(rec["arch"], rec["variant"])
    shape = configs_lib.SHAPES[rec["shape"]]
    cost = rec.get("cost_corrected") or rec["cost_raw"]
    flops = cost.get("flops", 0.0)              # per device
    hbm_bytes = cost.get("bytes accessed", 0.0)  # per device
    coll_bytes = cost.get("collective_bytes", 0.0)
    devices = rec.get("devices", 256)
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, rec["kind"])
    mf_per_dev = mf / devices
    ratio = mf_per_dev / flops if flops else 0.0
    bound = max(terms.values())
    frac_of_roofline = (mf_per_dev / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "key": f"{rec['arch']}/{rec['shape']}/{rec['variant']}",
        "kind": rec["kind"],
        "devices": devices,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": ratio,
        "roofline_fraction": frac_of_roofline,
        "collectives": rec["cost_raw"].get("_collectives", {}),
    }


def bottleneck_note(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.3:
            return ("compute-bound but low useful ratio: remat/recompute and "
                    "non-model flops dominate — reduce remat scope or fuse")
        return "compute-bound: healthy; push batch or quantize to gain"
    if d == "memory":
        return ("HBM-bound: raise arithmetic intensity (larger per-chip tile, "
                "fuse elementwise chains, bf16/8-bit weights for decode)")
    return ("collective-bound: reshard to cut all-gathers (see sharding "
            "rules), overlap collectives with compute, or compress")


def main(fast: bool = False, mesh: str = "single", write_md: bool = True):
    from benchmarks.common import emit

    rows = []
    for rec in load_cells(mesh):
        row = analyze_cell(rec)
        if row is None:
            continue
        rows.append(row)
        if "skipped" in row or "error" in row:
            emit(f"roofline/{row['key']}", 0, row.get("skipped") or row.get("error", ""))
            continue
        emit(
            f"roofline/{row['key']}", row[f"t_{row['dominant']}_s"] * 1e6,
            f"dom={row['dominant']};comp={row['t_compute_s']:.2e}s;"
            f"mem={row['t_memory_s']:.2e}s;coll={row['t_collective_s']:.2e}s;"
            f"useful={row['useful_ratio']:.2f};roofline_frac={row['roofline_fraction']:.2f}",
        )
    if write_md:
        write_markdown(rows, mesh)
    return rows


def write_markdown(rows, mesh, path=None):
    path = path or f"results/roofline_{mesh}.md"
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(f"# Roofline — {mesh}-pod mesh (v5e: 197 TF/s, 819 GB/s HBM, 50 GB/s link)\n\n")
        f.write("| cell | kind | compute (s) | memory (s) | collective (s) | dominant "
                "| MODEL_FLOPS | useful ratio | roofline frac | note |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            if "skipped" in r:
                f.write(f"| {r['key']} | — | — | — | — | — | — | — | — | SKIP: {r['skipped'][:60]} |\n")
                continue
            if "error" in r:
                f.write(f"| {r['key']} | — | — | — | — | — | — | — | — | ERROR |\n")
                continue
            f.write(
                f"| {r['key']} | {r['kind']} | {r['t_compute_s']:.2e} | "
                f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
                f"**{r['dominant']}** | {r['model_flops_global']:.2e} | "
                f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
                f"{bottleneck_note(r)} |\n"
            )
    print(f"[roofline] wrote {path}")


if __name__ == "__main__":
    main()
