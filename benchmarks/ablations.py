"""Paper Table 4 — ablation studies (WikiText-103 stand-in on the byte
corpus). Same grid as the paper:

  learnability:  full | fixed sigma,omega,T | omega=0 | fixed T
  node count:    S=4 | S=8 | S=16 | adaptive S_max=16 | no mask reg

Reports final validation CE per variant; the expected orderings (paper §4.4)
are checked by benchmarks.run and recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, emit, train_eval
from repro.data import ByteCorpus
from repro.models import layers as L
from repro.models import transformer as T


def _val_ce(cfg, corpus):
    def ev(params):
        ces = []
        for s in range(3):
            b = corpus.batch(2000 + s, 8, 128, split="val")
            logits, _ = T.apply_lm(params, cfg, jnp.asarray(b["inputs"]))
            ces.append(float(L.cross_entropy(logits, jnp.asarray(b["labels"]))))
        return float(np.mean(ces))
    return ev


VARIANTS = {
    "full_adaptive_S16": dict(stlt_nodes=16, stlt_adaptive=True),
    "fixed_sigma_omega_T": dict(stlt_learnable_sigma=False,
                                stlt_learnable_omega=False,
                                stlt_learnable_T=False),
    "omega_zero": dict(stlt_zero_omega=True),
    "fixed_T": dict(stlt_learnable_T=False),
    "S4": dict(stlt_nodes=4),
    "S8": dict(stlt_nodes=8),
    "S16": dict(stlt_nodes=16),
    "no_mask_reg": dict(stlt_nodes=16, stlt_adaptive=True, stlt_mask_reg=0.0),
}


def main(steps: int = 250, fast: bool = False):
    if fast:
        steps = min(steps, 120)
    corpus = ByteCorpus()
    batch_fn = lambda s: corpus.batch(s, 8, 128)
    results = {}
    for name, kw in VARIANTS.items():
        cfg = bench_cfg("stlt", **kw)
        t0 = time.time()
        _, ce, _ = train_eval(cfg, batch_fn, steps, eval_fn=_val_ce(cfg, corpus))
        us = (time.time() - t0) / steps * 1e6
        emit(f"ablation/{name}", us, f"val_ce={ce:.4f}")
        results[name] = ce
    return results


if __name__ == "__main__":
    main()
