"""Paper Table 4 — ablation studies (WikiText-103 stand-in on the byte
corpus). Same grid as the paper:

  learnability:  full | fixed sigma,omega,T | omega=0 | fixed T
  node count:    S=4 | S=8 | S=16 | adaptive S_max=16 | no mask reg

Reports final validation CE per variant; the expected orderings (paper §4.4)
are checked by benchmarks.run and recorded in EXPERIMENTS.md.

``main_quality_vs_s`` (CLI: ``--quality-only``) is the serving companion:
ONE trained S=16 model evaluated with its readout masked to the top-m nodes
per head for m in {4, 8, 16} — exactly the mask a served request decodes
under at ``serve_nodes=m`` (the engine's cap ranks nodes with the same
importance order, see repro.core.adaptive), so the CE-vs-m curve prices
each step of the SLO degrade ladder in BENCH_serving.json's
``slo_degradation`` row. Writes ``BENCH_ablations.json`` (a tier-1 CI
artifact).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, emit, train_eval
from repro.data import ByteCorpus
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.speculative import draft_params


def _val_ce(cfg, corpus):
    def ev(params):
        ces = []
        for s in range(3):
            b = corpus.batch(2000 + s, 8, 128, split="val")
            logits, _ = T.apply_lm(params, cfg, jnp.asarray(b["inputs"]))
            ces.append(float(L.cross_entropy(logits, jnp.asarray(b["labels"]))))
        return float(np.mean(ces))
    return ev


VARIANTS = {
    "full_adaptive_S16": dict(stlt_nodes=16, stlt_adaptive=True),
    "fixed_sigma_omega_T": dict(stlt_learnable_sigma=False,
                                stlt_learnable_omega=False,
                                stlt_learnable_T=False),
    "omega_zero": dict(stlt_zero_omega=True),
    "fixed_T": dict(stlt_learnable_T=False),
    "S4": dict(stlt_nodes=4),
    "S8": dict(stlt_nodes=8),
    "S16": dict(stlt_nodes=16),
    "no_mask_reg": dict(stlt_nodes=16, stlt_adaptive=True, stlt_mask_reg=0.0),
}


def main(steps: int = 250, fast: bool = False):
    if fast:
        steps = min(steps, 120)
    corpus = ByteCorpus()
    batch_fn = lambda s: corpus.batch(s, 8, 128)
    results = {}
    for name, kw in VARIANTS.items():
        cfg = bench_cfg("stlt", **kw)
        t0 = time.time()
        _, ce, _ = train_eval(cfg, batch_fn, steps, eval_fn=_val_ce(cfg, corpus))
        us = (time.time() - t0) / steps * 1e6
        emit(f"ablation/{name}", us, f"val_ce={ce:.4f}")
        results[name] = ce
    return results


def main_quality_vs_s(steps: int = 250, fast: bool = False):
    """Quality vs served node budget: train one S=16 model, then eval val CE
    with the readout masked to the top-m importance-ranked nodes per head
    (m in {4, 8, 16}; m == S is bit-identical to the unmasked model)."""
    if fast:
        steps = min(steps, 120)
    corpus = ByteCorpus()
    cfg = bench_cfg("stlt", stlt_nodes=16)
    ev = _val_ce(cfg, corpus)
    _, ce_full, params = train_eval(cfg, lambda s: corpus.batch(s, 8, 128),
                                    steps, eval_fn=ev)
    curve = {}
    for m in (4, 8, 16):
        ce = ev(draft_params(params, cfg, m))
        curve[f"S{m}"] = ce
        emit(f"ablation/quality_vs_s/S{m}", 0.0, f"val_ce={ce:.4f}")
    curve["full"] = ce_full
    if abs(curve["S16"] - ce_full) > 1e-6:
        print("# WARNING: top-16-of-16 mask is not the identity")
    if not curve["S4"] >= curve["S8"] >= curve["S16"]:
        print("# WARNING: val CE did not degrade monotonically with fewer nodes")
    out = {"profile": "fast" if fast else "full", "steps": steps,
           "rows": {"quality_vs_nodes": curve}}
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_ablations.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    return curve


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quality-only", action="store_true",
                    help="run only the quality-vs-serve_nodes curve and "
                         "write BENCH_ablations.json")
    args = ap.parse_args()
    if args.quality_only:
        main_quality_vs_s(fast=not args.full)
    else:
        main(fast=not args.full)
