"""Shared benchmark helpers: timing, CSV emission, tiny-train loops."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.launch.train import make_step
from repro.models import transformer as T

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (post-jit)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def bench_cfg(mixer="stlt", vocab=256, **kw) -> ModelConfig:
    base = dict(
        name=f"bench-{mixer}", family="lm", vocab=vocab, num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, mixer=mixer,
        stlt_nodes=16, stlt_chunk=32, act="gelu", norm="layernorm",
        dtype="float32", scan_layers=False, remat=False,
        blockwise_threshold=100_000,
    )
    base.update(kw)
    return ModelConfig(**base)


def train_eval(cfg: ModelConfig, batch_fn, steps: int, *, lr=3e-3, seed=0,
               eval_fn=None, log=False):
    """Train `steps`, return (final train CE EWMA, eval metric)."""
    tcfg = TrainConfig(total_steps=steps, warmup_steps=max(5, steps // 10),
                       learning_rate=lr, seed=seed)
    opt, step_fn = make_step(cfg, tcfg)
    params = T.init_lm(jax.random.key(seed), cfg)
    st = opt.init(params)
    ewma = None
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in batch_fn(s).items()
             if k in ("inputs", "labels", "mask")}
        params, st, m = step_fn(params, st, b, s)
        ce = float(m["ce"])
        ewma = ce if ewma is None else 0.9 * ewma + 0.1 * ce
        if log and s % 25 == 0:
            print(f"    step {s}: ce={ce:.3f}")
    ev = eval_fn(params) if eval_fn else None
    return ewma, ev, params
