"""Paper Table 2 proxy — seq2seq translation (WMT'14 En-De stand-in).

Reverse-copy task through the full encoder–decoder: the decoder must emit
the source reversed — requiring real cross-block information flow (the
paper's hybrid bilateral-encoder / unilateral-decoder / cross-STLT scheme).
BLEU proxy: exact token accuracy on held-out sequences.

Variants: attention enc-dec (Transformer-base row) vs STLT enc-dec
(bilateral + unilateral + cross-STLT).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig, TrainConfig
from repro.data import copy_task_batch
from repro.models import whisper as W
from repro.optim import clip_by_global_norm, make_optimizer, make_schedule
from repro.optim.adamw import apply_updates

VOCAB, SRC_LEN = 32, 8


def _cfg(mixer: str) -> ModelConfig:
    return ModelConfig(
        name=f"mt-{mixer}", family="encdec", vocab=VOCAB, num_layers=2,
        num_decoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, mixer=mixer, stlt_nodes=8, stlt_chunk=8, act="gelu",
        norm="layernorm", input_mode="tokens", dtype="float32",
        scan_layers=False, remat=False,
    )


def _train(cfg: ModelConfig, steps: int, lr=5e-3, seed=0):
    tcfg = TrainConfig(total_steps=steps, warmup_steps=10, learning_rate=lr)
    opt = make_optimizer("adamw")
    sched = make_schedule("cosine", lr, tcfg.warmup_steps, steps)

    @jax.jit
    def step_fn(params, st, batch, step):
        def loss_fn(p):
            return W.encdec_loss(p, cfg, batch)

        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        g, _ = clip_by_global_norm(g, 1.0)
        ups, st2 = opt.update(g, st, params, sched(step))
        return apply_updates(params, ups), st2, m

    params = W.init_encdec(jax.random.key(seed), cfg)
    st = opt.init(params)
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in
             copy_task_batch(seed, s, 16, SRC_LEN, VOCAB, reverse=False).items()}
        params, st, m = step_fn(params, st, b, s)
    return params


def _token_accuracy(params, cfg, n_batches=4):
    accs = []
    for s in range(n_batches):
        b = copy_task_batch(99, 10_000 + s, 16, SRC_LEN, VOCAB, reverse=False)
        logits = W.apply_encdec(params, cfg, jnp.asarray(b["enc_inputs"]),
                                jnp.asarray(b["dec_inputs"]))
        pred = np.asarray(jnp.argmax(logits, -1))
        accs.append((pred == b["labels"]).mean())
    return float(np.mean(accs))


def main(steps: int = 1200, fast: bool = False):
    if fast:
        steps = min(steps, 1000)
    results = {}
    for mixer in ("attention", "stlt"):
        cfg = _cfg(mixer)
        t0 = time.time()
        params = _train(cfg, steps)
        us = (time.time() - t0) / steps * 1e6
        acc = _token_accuracy(params, cfg)
        emit(f"translation/{mixer}", us, f"token_acc={acc:.3f}")
        results[mixer] = acc
    return results


if __name__ == "__main__":
    main()
