"""Kernel §Perf — the repo's first kernel-level bench file (→ BENCH_kernels.json).

Three row families over the fused STLT scan kernel (``repro.kernels.ops``),
swept over S ∈ {8, 32, 128} nodes × N ∈ {1k, 4k, 16k} tokens:

1. ``fwd``: one fused scan pass — wall-clock per call and kernel dispatch
   count (always 1; the baseline the serving rows are judged against).
2. ``resume``: a state-resumed prefill chunk (h0 != 0), CARRY-NATIVE
   (``ops.stlt_scan(h0_re=..., return_state=True)`` — ONE kernel dispatch,
   the state snapshotted in-kernel) vs the legacy LINEARITY-FOLDED path the
   PR 2-4 serving engines used (zero-state kernel pass + the
   ``stlt_carry_outputs`` free-response full pass + the closed-form
   ``stlt_final_state`` full pass). Reports wall-clock for both, the
   speedup, and the per-trace kernel dispatch counts (1 vs 1 + two O(N*S*d)
   jnp passes).
3. ``bwd``: full gradient of sum(z^2) through the custom VJP — the ANALYTIC
   parameter-grad path (lag-correlation dg + adjoint-carry operator
   cotangents, DESIGN.md §3) vs the legacy per-node jnp recompute
   (``param_grads="recompute"``). The recompute sweep is trimmed in the
   fast profile (it materializes O(N*S*d) per-chunk tensors — the point).
4. ``relevance``: the flash-tiled relevance kernel
   (``repro.kernels.relevance_flash``) vs the materialized O(N^2) readout,
   N ∈ {1k, 4k, 32k}. The materialized comparator is SKIPPED past the
   memory cliff where its ~3 N^2 fp32 buffers stop fitting (the skip and
   its reason are logged in the row — no silent caps); the tiled kernel
   must survive every N in ONE pallas dispatch without ever holding
   [BH, N, N].

On non-TPU hosts the kernel runs in interpret mode (same dispatch
structure, wall numbers are indicative only — the dispatch counts and the
relative resume/bwd gaps are the hardware-independent claims). ``main``
writes the full row dicts to ``BENCH_kernels.json`` (a CI artifact next to
``BENCH_serving.json``).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import scan as scan_lib
from repro.kernels import ops
from repro.kernels import relevance_flash as rflash
from repro.utils import trace_probe

CHUNK = 128
BH = 2
D = 64

# relevance family: small head so the O(N^2) comparator fits at 4k while the
# 32k row still exercises a >500-tile grid
REL_S = 8
REL_DH = 16
REL_BH = 1
REL_CLIFF_BYTES = 2 << 30


def _inputs(N, S, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(BH, N, D)), jnp.float32)
    lm = jnp.asarray(-rng.uniform(0.005, 1.0, (BH, S)), jnp.float32)
    th = jnp.asarray(-rng.uniform(0, 1.5, (BH, S)), jnp.float32)
    ur = jnp.asarray(rng.normal(size=(BH, S)) / S, jnp.float32)
    ui = jnp.asarray(rng.normal(size=(BH, S)) / S, jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(2, BH, S, D)), jnp.float32)
    return x, lm, th, ur, ui, h0


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _dispatches(fn, *args):
    """(kernel dispatches, legacy full-sequence passes) per call of ``fn``
    — trace_probe on the pallas_call wrapper and on the linearity-folding
    helpers; each probed call site is one dispatch in the traced program.
    Runs ``fn`` eagerly (outside jit) so probe counts are not hidden by
    jax's function-identity trace cache."""
    klog: list = []
    llog: list = []
    orig_k = ops.stlt_scan_kernel
    orig_c = scan_lib.stlt_carry_outputs
    orig_f = scan_lib.stlt_final_state
    ops.stlt_scan_kernel = trace_probe(orig_k, klog, "kernel")
    scan_lib.stlt_carry_outputs = trace_probe(orig_c, llog, "carry_outputs")
    scan_lib.stlt_final_state = trace_probe(orig_f, llog, "final_state")
    try:
        jax.block_until_ready(fn(*args))
    finally:
        ops.stlt_scan_kernel = orig_k
        scan_lib.stlt_carry_outputs = orig_c
        scan_lib.stlt_final_state = orig_f
    return len(klog), len(llog)


def _scan_kwargs():
    # real kernel on TPU; interpret-mode kernel elsewhere (same dispatches)
    if jax.default_backend() == "tpu":
        return {}
    return {"interpret": True, "block_d": D}


def bench_forward(sweep):
    rows = []
    kw = _scan_kwargs()
    for S, N in sweep:
        x, lm, th, ur, ui, _ = _inputs(N, S)
        fn = jax.jit(lambda x, lm, th, ur, ui: ops.stlt_scan(
            x, lm, th, ur, ui, chunk=CHUNK, **kw))
        us = _time(fn, x, lm, th, ur, ui)
        nd, _ = _dispatches(
            lambda x: ops.stlt_scan(x, lm, th, ur, ui, chunk=CHUNK, **kw), x)
        emit(f"kernels/fwd/S{S}/N{N}", us, f"dispatches={nd}")
        rows.append({"family": "fwd", "S": S, "N": N, "us": us,
                     "dispatches": nd})
    return rows


def bench_resume(sweep):
    """Carry-native one-pass resume vs the legacy linearity-folded path."""
    rows = []
    kw = _scan_kwargs()
    for S, N in sweep:
        x, lm, th, ur, ui, h0 = _inputs(N, S)
        # shared poles across rows for the legacy helpers' [H, S] contract
        # (rows become batch, one head)
        lm1, th1, ur1, ui1 = (a[:1] for a in (lm, th, ur, ui))
        lmb, thb, urb, uib = (jnp.tile(a[:1], (BH, 1))
                              for a in (lm, th, ur, ui))

        def native(x, h0r, h0i):
            return ops.stlt_scan(x, lmb, thb, urb, uib, chunk=CHUNK,
                                 h0_re=h0r, h0_im=h0i, return_state=True,
                                 **kw)

        def legacy(x, h0r, h0i):
            z = ops.stlt_scan(x, lmb, thb, urb, uib, chunk=CHUNK, **kw)
            z = z + scan_lib.stlt_carry_outputs(
                h0r[:, None], h0i[:, None], lm1, th1, ur1, ui1,
                N)[:, 0].astype(z.dtype)
            h_re, h_im = scan_lib.stlt_final_state(
                x[:, None], lm1, th1, h0r[:, None], h0i[:, None])
            return z, (h_re[:, 0], h_im[:, 0])

        jn = jax.jit(native)
        jl = jax.jit(legacy)
        zn, (hr_n, hi_n) = jn(x, h0[0], h0[1])
        zl, (hr_l, hi_l) = jl(x, h0[0], h0[1])
        err = float(jnp.max(jnp.abs(zn - zl)))
        us_n = _time(jn, x, h0[0], h0[1])
        us_l = _time(jl, x, h0[0], h0[1])
        kn, ln = _dispatches(native, x, h0[0], h0[1])
        kl, ll = _dispatches(legacy, x, h0[0], h0[1])
        emit(f"kernels/resume_native/S{S}/N{N}", us_n,
             f"kernel={kn};full_passes={ln};"
             f"speedup={us_l / max(us_n, 1e-9):.2f}x")
        emit(f"kernels/resume_legacy/S{S}/N{N}", us_l,
             f"kernel={kl};full_passes={ll}")
        rows.append({"family": "resume", "S": S, "N": N,
                     "native_us": us_n, "legacy_us": us_l,
                     "speedup": us_l / max(us_n, 1e-9),
                     "native_kernel_dispatches": kn,
                     "native_full_passes": ln,
                     "legacy_kernel_dispatches": kl,
                     "legacy_full_passes": ll,
                     "z_max_abs_diff": err})
    return rows


def bench_backward(sweep, recompute_sweep):
    rows = []
    kw = _scan_kwargs()
    for S, N in sweep:
        x, lm, th, ur, ui, _ = _inputs(N, S)

        def make_loss(mode):
            def loss(x, lm, th, ur, ui):
                z = ops.stlt_scan(x, lm, th, ur, ui, chunk=CHUNK,
                                  param_grads=mode, **kw)
                return (z ** 2).sum()

            return jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3, 4)))

        us_a = _time(make_loss("analytic"), x, lm, th, ur, ui)
        row = {"family": "bwd", "S": S, "N": N, "analytic_us": us_a}
        if (S, N) in recompute_sweep:
            us_r = _time(make_loss("recompute"), x, lm, th, ur, ui)
            row["recompute_us"] = us_r
            row["speedup"] = us_r / max(us_a, 1e-9)
            emit(f"kernels/bwd_analytic/S{S}/N{N}", us_a,
                 f"vs_recompute={row['speedup']:.2f}x")
            emit(f"kernels/bwd_recompute/S{S}/N{N}", us_r, "per-node jnp")
        else:
            emit(f"kernels/bwd_analytic/S{S}/N{N}", us_a, "")
        rows.append(row)
    return rows


def _rel_inputs(N, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(REL_BH, N, REL_DH)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(REL_BH, N, REL_DH)), jnp.float32)
    lm = jnp.asarray(-rng.uniform(0.005, 1.0, (REL_BH, REL_S)), jnp.float32)
    th = jnp.asarray(-rng.uniform(0, 1.5, (REL_BH, REL_S)), jnp.float32)
    return x, v, lm, th


def _rel_materialized(x, v, lm, th):
    """The O(N^2) comparator the flash kernel replaces: full coefficient
    scan, full [BH, N, N] relevance matrix, causal softmax."""
    B, N, dh = x.shape
    S = lm.shape[-1]
    lam = jnp.exp(lm + 1j * th).astype(jnp.complex64)
    xc = jnp.broadcast_to(x[:, :, None, :].astype(jnp.complex64),
                          (B, N, S, dh))
    a = jnp.broadcast_to(lam[:, None, :, None], xc.shape)
    L = scan_lib.scan_associative(a, xc, axis=-3)
    R = jnp.einsum("bnkd,bmkd->bnm", L, jnp.conj(L)).real
    R = R / jnp.sqrt(float(S))
    R = jnp.where(jnp.tril(jnp.ones((N, N), bool))[None], R, -jnp.inf)
    return jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(R, axis=-1), v)


def _rel_dispatches(fn, *args):
    """Pallas dispatches per eager call of ``fn`` (trace_probe on the
    flash kernel wrapper, same scheme as ``_dispatches``)."""
    klog: list = []
    orig = rflash.relevance_flash_kernel
    rflash.relevance_flash_kernel = trace_probe(orig, klog, "flash")
    try:
        jax.block_until_ready(fn(*args))
    finally:
        rflash.relevance_flash_kernel = orig
    return len(klog)


def bench_relevance(Ns=(1024, 4096, 32768)):
    rows = []
    kernel_kw = {} if jax.default_backend() == "tpu" else {"interpret": True}
    for N in Ns:
        # 128 matches cfg.chunk defaults; the 32k row widens the tile so the
        # interpret-mode grid stays tractable off-TPU (still >500 tiles)
        tile = 128 if N <= 4096 else 1024
        x, v, lm, th = _rel_inputs(N)

        def tiled(x, v):
            return rflash.relevance_flash(x, v, lm, th, causal=True,
                                          tile=tile, **kernel_kw)

        iters = 3 if N <= 4096 else 1
        us_t = _time(jax.jit(tiled), x, v, iters=iters)
        nd = _rel_dispatches(tiled, x, v)
        row = {"family": "relevance", "S": REL_S, "N": N, "tile": tile,
               "head_dim": REL_DH, "batch_rows": REL_BH, "tiled_us": us_t,
               "tiled_dispatches": nd}
        mat_bytes = 3 * REL_BH * N * N * 4  # R + masked R + softmax probs
        if mat_bytes <= REL_CLIFF_BYTES:
            mat = jax.jit(lambda x, v: _rel_materialized(x, v, lm, th))
            err = float(jnp.max(jnp.abs(tiled(x, v) - mat(x, v))))
            us_m = _time(mat, x, v, iters=iters)
            row["materialized_us"] = us_m
            row["max_abs_diff"] = err
            emit(f"kernels/relevance_tiled/N{N}", us_t,
                 f"dispatches={nd};materialized_us={us_m:.0f};"
                 f"maxdiff={err:.1e}")
        else:
            row["materialized_skipped"] = (
                f"memory cliff: ~{mat_bytes / 2**30:.1f} GiB of N^2 "
                f"buffers > {REL_CLIFF_BYTES / 2**30:.0f} GiB budget")
            emit(f"kernels/relevance_tiled/N{N}", us_t,
                 f"dispatches={nd};materialized=SKIPPED("
                 f"{row['materialized_skipped']})")
        rows.append(row)
    return rows


def main(fast: bool = True):
    sweep = [(S, N) for S in (8, 32, 128) for N in (1024, 4096, 16384)]
    if fast:
        # the O(N*C*S*d) recompute baseline is the point being beaten; cap
        # it where it stays CI-friendly (the acceptance pair S=32/N=4096
        # always runs)
        recompute_sweep = {(8, 1024), (8, 4096), (32, 1024), (32, 4096),
                           (128, 1024)}
    else:
        recompute_sweep = set(sweep)
    rows = []
    rows += bench_forward(sweep)
    rows += bench_resume(sweep)
    rows += bench_backward(sweep, recompute_sweep)
    rows += bench_relevance()
    out = {
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "chunk": CHUNK,
        "batch_rows": BH,
        "head_dim": D,
        "rows": rows,
    }
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernels.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"# wrote {path}", flush=True)
    return out


if __name__ == "__main__":
    main()
